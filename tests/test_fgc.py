"""Unit + property tests for the paper's core: FGC operators (§3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fgc

RNG = np.random.default_rng(0)
BACKENDS = ("scan", "cumsum", "pallas")


@pytest.mark.parametrize("n", [2, 5, 17, 64, 257])
@pytest.mark.parametrize("p", [0, 1, 2, 3])
@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_abs_power_matches_dense(n, p, backend):
    x = jnp.asarray(RNG.normal(size=(n, 3)))
    want = fgc.apply_abs_power(x, 0, p, "dense")
    got = fgc.apply_abs_power(x, 0, p, backend)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9 * n ** p)


@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_L_strictly_lower(backend):
    """(Lx)_0 must be 0 and (Lx)_i independent of x_j for j >= i."""
    n = 32
    x = jnp.asarray(RNG.normal(size=(n, 1)))
    y = fgc.apply_L(x, 0, 2, backend)
    assert float(jnp.abs(y[0]).max()) < 1e-12
    x2 = x.at[20:].set(123.0)
    y2 = fgc.apply_L(x2, 0, 2, backend)
    np.testing.assert_allclose(y[:21], y2[:21], rtol=1e-12)


@pytest.mark.parametrize("axis", [0, 1, 2])
def test_axis_handling(axis):
    x = jnp.asarray(RNG.normal(size=(6, 7, 8)))
    a = fgc.apply_abs_power(x, axis, 2, "cumsum")
    b = fgc.apply_abs_power(x, axis, 2, "dense")
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)


def test_LT_is_transpose_of_L():
    n = 40
    lo = np.asarray(fgc.lower_toeplitz(n, 2))
    x = jnp.asarray(RNG.normal(size=(n, 2)))
    got = fgc.apply_LT(x, 0, 2, "scan")
    np.testing.assert_allclose(got, lo.T @ np.asarray(x), rtol=1e-9,
                               atol=1e-9)


def test_pascal_matrix():
    p = np.asarray(fgc.pascal_matrix(3))
    want = np.array([[1, 0, 0, 0], [1, 1, 0, 0], [1, 2, 1, 0], [1, 3, 3, 1]])
    np.testing.assert_array_equal(p, want)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 40), p=st.integers(1, 3),
       seed=st.integers(0, 1000))
def test_property_backends_agree(n, p, seed):
    """The paper's DP recursion and the binomial-cumsum closed form are the
    same linear operator (hypothesis sweep)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(n, 2)))
    a = fgc.apply_abs_power(x, 0, p, "scan")
    b = fgc.apply_abs_power(x, 0, p, "cumsum")
    np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-8 * n ** p)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_linearity(seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(20, 1)))
    y = jnp.asarray(r.normal(size=(20, 1)))
    a, b = 2.5, -1.25
    lhs = fgc.apply_abs_power(a * x + b * y, 0, 2, "scan")
    rhs = (a * fgc.apply_abs_power(x, 0, 2, "scan")
           + b * fgc.apply_abs_power(y, 0, 2, "scan"))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


def test_flops_estimate_matches_paper():
    # paper §3: (N−1)·k(k+1)/2 muls + (N−1)(k+2)(k+1)/2 adds
    assert fgc.flops_estimate(100, 1) == 99 * (1 + 3)
    assert fgc.flops_estimate(100, 2) == 99 * (3 + 6)
