"""Unit + property tests for the paper's core: FGC operators (§3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import fgc

RNG = np.random.default_rng(0)
BACKENDS = ("scan", "cumsum", "pallas")


@pytest.mark.parametrize("n", [2, 5, 17, 64, 257])
@pytest.mark.parametrize("p", [0, 1, 2, 3])
@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_abs_power_matches_dense(n, p, backend):
    x = jnp.asarray(RNG.normal(size=(n, 3)))
    want = fgc.apply_abs_power(x, 0, p, "dense")
    got = fgc.apply_abs_power(x, 0, p, backend)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9 * n ** p)


@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_L_strictly_lower(backend):
    """(Lx)_0 must be 0 and (Lx)_i independent of x_j for j >= i."""
    n = 32
    x = jnp.asarray(RNG.normal(size=(n, 1)))
    y = fgc.apply_L(x, 0, 2, backend)
    assert float(jnp.abs(y[0]).max()) < 1e-12
    x2 = x.at[20:].set(123.0)
    y2 = fgc.apply_L(x2, 0, 2, backend)
    np.testing.assert_allclose(y[:21], y2[:21], rtol=1e-12)


@pytest.mark.parametrize("axis", [0, 1, 2])
def test_axis_handling(axis):
    x = jnp.asarray(RNG.normal(size=(6, 7, 8)))
    a = fgc.apply_abs_power(x, axis, 2, "cumsum")
    b = fgc.apply_abs_power(x, axis, 2, "dense")
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)


def test_LT_is_transpose_of_L():
    n = 40
    lo = np.asarray(fgc.lower_toeplitz(n, 2))
    x = jnp.asarray(RNG.normal(size=(n, 2)))
    got = fgc.apply_LT(x, 0, 2, "scan")
    np.testing.assert_allclose(got, lo.T @ np.asarray(x), rtol=1e-9,
                               atol=1e-9)


def test_pascal_matrix():
    p = np.asarray(fgc.pascal_matrix(3))
    want = np.array([[1, 0, 0, 0], [1, 1, 0, 0], [1, 2, 1, 0], [1, 3, 3, 1]])
    np.testing.assert_array_equal(p, want)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 40), p=st.integers(1, 3),
       seed=st.integers(0, 1000))
def test_property_backends_agree(n, p, seed):
    """The paper's DP recursion and the binomial-cumsum closed form are the
    same linear operator (hypothesis sweep)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(n, 2)))
    a = fgc.apply_abs_power(x, 0, p, "scan")
    b = fgc.apply_abs_power(x, 0, p, "cumsum")
    np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-8 * n ** p)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_linearity(seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(20, 1)))
    y = jnp.asarray(r.normal(size=(20, 1)))
    a, b = 2.5, -1.25
    lhs = fgc.apply_abs_power(a * x + b * y, 0, 2, "scan")
    rhs = (a * fgc.apply_abs_power(x, 0, 2, "scan")
           + b * fgc.apply_abs_power(y, 0, 2, "scan"))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("n", [2, 7, 16, 33, 64, 101])
@pytest.mark.parametrize("p", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_dtilde_matches_dense_oracle(n, p, backend):
    """Fused single-sweep D̃ backends vs the explicit lo + lo.T oracle,
    p ∈ {0..4}, odd and even N (f64)."""
    x = jnp.asarray(RNG.normal(size=(n, 2)))
    if p == 0:
        want = np.ones((n, n)) @ np.asarray(x)     # 0^0 := 1 on the diagonal
    else:
        lo = np.asarray(fgc.lower_toeplitz(n, p))
        want = (lo + lo.T) @ np.asarray(x)
    got = np.asarray(fgc.apply_abs_power(x, 0, p, backend))
    np.testing.assert_allclose(got, want, rtol=1e-9,
                               atol=1e-9 * max(1.0, float(n) ** p))


@pytest.mark.parametrize("p", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_dtilde_f32(p, backend):
    """Acceptance tolerance: fused D̃ within 1e-5 rtol of dense in f32."""
    x = jnp.asarray(RNG.normal(size=(200, 4)), dtype=jnp.float32)
    want = np.asarray(fgc.apply_abs_power(x, 0, p, "dense"))
    got = np.asarray(fgc.apply_abs_power(x, 0, p, backend))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-5 * np.abs(want).max())


def test_fused_scan_is_single_sweep():
    """The fused scan backend must lower to exactly ONE lax.scan (the
    bidirectional sweep), not the historical L-pass + flip/L/flip pass."""
    x = jnp.asarray(RNG.normal(size=(33, 2)))
    jaxpr = jax.make_jaxpr(lambda v: fgc.apply_abs_power(v, 0, 2, "scan"))(x)
    scans = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "scan"]
    assert len(scans) == 1, jaxpr


def test_fused_matches_two_pass():
    """Fused D̃ must equal the explicit L + Lᵀ composition per backend."""
    x = jnp.asarray(RNG.normal(size=(47, 3)))
    for p in (1, 2, 3):
        for backend in ("scan", "cumsum"):
            fused = fgc.apply_abs_power(x, 0, p, backend)
            two = (fgc.apply_L(x, 0, p, backend)
                   + fgc.apply_LT(x, 0, p, backend))
            np.testing.assert_allclose(np.asarray(fused), np.asarray(two),
                                       rtol=1e-9, atol=1e-9 * 47.0 ** p)


def test_flops_estimate_matches_paper():
    # paper §3: (N−1)·k(k+1)/2 muls + (N−1)(k+2)(k+1)/2 adds
    assert fgc.flops_estimate(100, 1) == 99 * (1 + 3)
    assert fgc.flops_estimate(100, 2) == 99 * (3 + 6)
