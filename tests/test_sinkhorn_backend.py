"""Solver- and serving-level contracts for the Sinkhorn backend layer.

What "bit-identical" can and cannot mean here: the XLA log-domain
expressions themselves round differently between eager and scan-fused
trace contexts (~1 ulp on the potentials — observed 5.6e-17 after 30
sweeps), so literal cross-backend bit equality is unattainable even in
principle.  The contracts this suite pins are therefore:

  * WITHIN the Pallas backend, every scheduling invariance is EXACT
    (``assert_array_equal``): chunked tol=0 == fixed scan, warm starts,
    segmented batch == one-shot batch, continuous serving == barrier
    serving.  These are the invariances the continuous-batching engine
    relies on, now with the fused kernels in the loop.
  * ACROSS backends (pallas vs xla), plans/potentials agree to ≤1 ulp per
    sweep (pinned at rtol 1e-12) and every iteration COUNT — outer, inner,
    chunked iters_used — is exactly equal, so the adaptive driver's
    control flow is backend-invariant.
  * No jit recompilation with the kernel enabled: ε-annealing stages and
    `SolveControls` retuning reuse one executable (ε reaches the kernel as
    a traced SMEM operand).

On this CPU container the Pallas path runs in interpret mode
(`backend="pallas"` forces it; `"auto"` resolves to the XLA scans off-TPU).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sinkhorn as sk
from repro.core.grids import Grid1D
from repro.core.gw import (GWConfig, _solve_stacked, entropic_gw,
                           entropic_gw_batch)
from repro.kernels import sinkhorn_step
from repro.serve.engine import GWEngine, GWServeConfig

RNG = np.random.default_rng(23)


def _measure(n, seed):
    r = np.random.default_rng(seed)
    u = r.random(n) + 0.1
    return jnp.asarray(u / u.sum())


def _problem(m, n, seed):
    cost = jnp.asarray(np.random.default_rng(seed).random((m, n)))
    return cost, _measure(m, 2 * seed), _measure(n, 2 * seed + 1)


def _grid_problem(m, n, seed):
    return (Grid1D(m, 1 / (m - 1), 1), Grid1D(n, 1 / (n - 1), 1),
            _measure(m, 2 * seed), _measure(n, 2 * seed + 1))


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# sinkhorn-level: pallas vs xla, fixed + chunked + warm starts
# ---------------------------------------------------------------------------

def test_pallas_matches_xla_fixed_and_chunked():
    cost, mu, nu = _problem(48, 64, 3)
    for call in [
        lambda be: sk.sinkhorn_log(cost, mu, nu, 0.01, 30, backend=be),
        lambda be: sk.sinkhorn_log_chunked(cost, mu, nu, 0.01, 30, 8, 0.0,
                                           backend=be),
        lambda be: sk.sinkhorn_log_chunked(cost, mu, nu, 0.01, 300, 10,
                                           1e-8, backend=be),
    ]:
        x = call("xla")
        p = call("pallas")
        for xa, pa in zip(x[:4], p[:4]):     # plan, f, g, err
            np.testing.assert_allclose(np.asarray(pa), np.asarray(xa),
                                       rtol=1e-12, atol=1e-13)
        if len(x) == 5:                      # chunked: identical stop step
            assert int(x[4]) == int(p[4])


def test_pallas_chunked_tol0_bit_identical_to_fixed():
    """The repo's oldest Sinkhorn contract — tol=0 chunked == fixed scan,
    bit for bit — must survive with the kernels in the loop (cold and warm
    starts, odd sizes)."""
    for (m, n), seed in [((37, 53), 5), ((48, 64), 6)]:
        cost, mu, nu = _problem(m, n, seed)
        r = np.random.default_rng(seed)
        for f0, g0 in [(None, None),
                       (jnp.asarray(r.normal(size=(m,)) * 0.01),
                        jnp.asarray(r.normal(size=(n,)) * 0.01))]:
            fixed = sk.sinkhorn_log(cost, mu, nu, 0.01, 25, f0, g0,
                                    backend="pallas")
            chunk = sk.sinkhorn_log_chunked(cost, mu, nu, 0.01, 25, 7, 0.0,
                                            f0, g0, backend="pallas")
            assert int(chunk[4]) == 25
            _assert_trees_equal(fixed, chunk[:4])


def test_pallas_warm_start_matches_xla():
    cost, mu, nu = _problem(40, 48, 7)
    r = np.random.default_rng(7)
    f0 = jnp.asarray(r.normal(size=(40,)) * 0.01)
    g0 = jnp.asarray(r.normal(size=(48,)) * 0.01)
    x = sk.sinkhorn_log_chunked(cost, mu, nu, 5e-3, 20, 5, 0.0, f0, g0,
                                backend="xla")
    p = sk.sinkhorn_log_chunked(cost, mu, nu, 5e-3, 20, 5, 0.0, f0, g0,
                                backend="pallas")
    for xa, pa in zip(x[:4], p[:4]):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(xa),
                                   rtol=1e-12, atol=1e-13)


def test_bf16_cost_tiles_parity_bound():
    """``cost_dtype="bf16"`` streams the cost / log-kernel tiles through
    bfloat16 with full-precision accumulators: results stay in the CALLER's
    dtype and track the f32-tile path to bf16's ~2⁻⁸ relative precision —
    a bandwidth knob, not a different algorithm."""
    cost, mu, nu = _problem(24, 28, 3)
    f32 = sk.sinkhorn_log_chunked(cost, mu, nu, 5e-2, 60, 20, 0.0,
                                  backend="pallas")
    b16 = sk.sinkhorn_log_chunked(cost, mu, nu, 5e-2, 60, 20, 0.0,
                                  backend="pallas", cost_dtype="bf16")
    assert b16[0].dtype == cost.dtype            # caller dtype preserved
    scale = float(jnp.abs(f32[0]).max())
    assert float(jnp.abs(b16[0] - f32[0]).max()) <= 2e-2 * scale
    # marginals stay feasible to the same order (duals are full precision)
    assert float(jnp.abs(b16[0].sum(1) - mu).sum()) <= 1e-2

    # end-to-end: full and factored GW values track f32 within the bound
    gx, gy = Grid1D(24, 1 / 23, 1), Grid1D(28, 1 / 27, 1)
    for kw in ({"sinkhorn_backend": "pallas"},
               {"plan": "lowrank", "plan_rank": 6, "lr_gamma": 5.0,
                "lowrank_backend": "pallas"}):
        cfgf = GWConfig(eps=5e-2, outer_iters=8, sinkhorn_iters=100, **kw)
        cfgb = GWConfig(eps=5e-2, outer_iters=8, sinkhorn_iters=100,
                        cost_dtype="bf16", **kw)
        vf = float(entropic_gw(gx, gy, mu, nu, cfgf).value)
        vb = float(entropic_gw(gx, gy, mu, nu, cfgb).value)
        np.testing.assert_allclose(vb, vf, rtol=2e-2)

    # the XLA expressions ignore the knob entirely (bit-identical)
    xf = sk.sinkhorn_log_chunked(cost, mu, nu, 5e-2, 60, 20, 0.0,
                                 backend="xla")
    xb = sk.sinkhorn_log_chunked(cost, mu, nu, 5e-2, 60, 20, 0.0,
                                 backend="xla", cost_dtype="bf16")
    np.testing.assert_array_equal(np.asarray(xf[0]), np.asarray(xb[0]))


def test_grad_flows_through_pallas_backend():
    """`pallas_call` has no VJP, but the solver's implicit surface
    (core.solver.fixed_point_value) differentiates AROUND the forward
    solve: jax.grad through entropic_gw runs with backend="pallas" — no
    XLA fallback, no unroll — and matches the XLA backend's gradient."""
    n = 12
    u = RNG.random(n) + 0.05
    mu = jnp.asarray(u / u.sum())

    def loss(h, backend):
        g = Grid1D(n, h, 1)
        cfg = GWConfig(eps=5e-2, outer_iters=8, sinkhorn_iters=120,
                       sinkhorn_backend=backend)
        return entropic_gw(g, g, mu, mu, cfg).value

    gp = jax.grad(loss)(0.1, "pallas")
    gx = jax.grad(loss)(0.1, "xla")
    assert np.isfinite(float(gp))
    np.testing.assert_allclose(float(gp), float(gx), rtol=1e-9)


# ---------------------------------------------------------------------------
# solver-level: GW mirror descent with annealing
# ---------------------------------------------------------------------------

def test_gw_pallas_matches_xla_with_annealing():
    """End-to-end entropic GW under ε-annealing + early stopping: identical
    control flow (outer/inner counts), ulp-level plans."""
    gx, gy, mu, nu = _grid_problem(40, 40, 13)
    base = GWConfig(eps=5e-3, outer_iters=12, sinkhorn_iters=80, tol=1e-6,
                    eps_init=0.05, anneal_decay=0.5)
    x = entropic_gw(gx, gy, mu, nu,
                    dataclasses.replace(base, sinkhorn_backend="xla"))
    p = entropic_gw(gx, gy, mu, nu,
                    dataclasses.replace(base, sinkhorn_backend="pallas"))
    assert int(x.info.outer_iters) == int(p.info.outer_iters)
    assert int(x.info.inner_iters) == int(p.info.inner_iters)
    assert bool(x.info.converged) == bool(p.info.converged)
    np.testing.assert_allclose(np.asarray(p.plan), np.asarray(x.plan),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(float(p.value), float(x.value), rtol=1e-10)


def test_gw_batch_zero_mass_padded_lanes_pallas():
    """Ragged problems padded with zero-mass atoms (−inf potentials — the
    `_finish` hazard the kernel guards) must solve NaN-free through the
    kernels and match the xla-backend batch lane for lane."""
    probs = [_grid_problem(m, n, 17 + i)
             for i, (m, n) in enumerate([(30, 40), (40, 30), (25, 37)])]
    base = GWConfig(eps=1e-2, outer_iters=8, sinkhorn_iters=60, tol=1e-6)
    out_x = entropic_gw_batch(
        probs, dataclasses.replace(base, sinkhorn_backend="xla"),
        pad_to=(40, 40))
    out_p = entropic_gw_batch(
        probs, dataclasses.replace(base, sinkhorn_backend="pallas"),
        pad_to=(40, 40))
    for rx, rp in zip(out_x, out_p):
        assert not bool(jnp.isnan(rp.plan).any())
        assert bool(jnp.isfinite(rp.f).all())   # sliced back: no pad atoms
        assert int(rx.info.inner_iters) == int(rp.info.inner_iters)
        np.testing.assert_allclose(np.asarray(rp.plan), np.asarray(rx.plan),
                                   rtol=1e-10, atol=1e-12)


def test_gw_batch_segmented_bit_identical_pallas():
    """Segmented (continuous-batching) solves with the kernel enabled visit
    the same iterates, bit for bit, as one uninterrupted batch."""
    probs = [_grid_problem(s, s, 29 + i) for i, s in enumerate((30, 40, 36))]
    cfg = GWConfig(eps=1e-2, outer_iters=8, sinkhorn_iters=60, tol=1e-6,
                   sinkhorn_backend="pallas")
    one = entropic_gw_batch(probs, cfg, pad_to=(40, 40))
    res, carry = entropic_gw_batch(probs, cfg, pad_to=(40, 40),
                                   max_outer_segment=3)
    while not bool(jnp.all(carry.done | (carry.t >= cfg.outer_iters))):
        res, carry = entropic_gw_batch(probs, cfg, pad_to=(40, 40),
                                       resume_state=carry,
                                       max_outer_segment=3)
    for o, s in zip(one, res):
        _assert_trees_equal((o.plan, o.f, o.g), (s.plan, s.f, s.g))
        assert int(o.info.inner_iters) == int(s.info.inner_iters)


# ---------------------------------------------------------------------------
# no recompilation with the kernel enabled
# ---------------------------------------------------------------------------

def test_no_recompile_annealing_and_retuning_with_pallas():
    """Mirrors tests/test_solver.py's no-recompile asserts with the fused
    kernel in the loop: retuning tol/ε/annealing (traced `SolveControls` +
    traced kernel ε) must reuse the compiled bucket executable."""
    _solve_stacked.clear_cache()
    probs = [_grid_problem(20, 20, 41)]
    base = GWConfig(eps=5e-2, outer_iters=8, sinkhorn_iters=60, tol=1e-5,
                    sinkhorn_backend="pallas")
    entropic_gw_batch(probs, base)
    n0 = _solve_stacked._cache_size()
    for cfg in [dataclasses.replace(base, tol=1e-7),
                dataclasses.replace(base, eps=1e-2),
                dataclasses.replace(base, eps_init=0.1, anneal_decay=0.7),
                dataclasses.replace(base, eps_init=0.2, anneal_decay=0.4)]:
        entropic_gw_batch(probs, cfg)
    assert _solve_stacked._cache_size() == n0
    # flipping the backend is structural: exactly one new executable
    entropic_gw_batch(probs,
                      dataclasses.replace(base, sinkhorn_backend="xla"))
    assert _solve_stacked._cache_size() == n0 + 1


# ---------------------------------------------------------------------------
# serving: the continuous-batching scheduler on fused sweeps
# ---------------------------------------------------------------------------

def test_serving_continuous_equals_barrier_on_pallas():
    """The engine's strongest invariance — continuous slot scheduling
    returns bit-identical results to the flush-barrier baseline — must hold
    with the kernels doing every inner sweep; vs the unbatched solver the
    lanes match at ulp level with EXACT iteration counts."""
    solver = GWConfig(eps=1e-2, outer_iters=10, sinkhorn_iters=60, tol=1e-6,
                      sinkhorn_backend="pallas")
    probs = [_grid_problem(s, s, 47 + i)
             for i, s in enumerate((30, 40, 36, 25))]
    outs = {}
    for sched in ("continuous", "barrier"):
        eng = GWEngine(GWServeConfig(solver=solver, max_batch=4,
                                     size_bucket=64, scheduler=sched,
                                     segment_iters=3))
        rids = [eng.submit(*p) for p in probs]
        res = eng.flush()
        assert sorted(res) == sorted(rids)
        outs[sched] = [res[r] for r in rids]
    for c, b in zip(outs["continuous"], outs["barrier"]):
        _assert_trees_equal((c.plan, c.f, c.g), (b.plan, b.f, b.g))
        assert int(c.info.inner_iters) == int(b.info.inner_iters)
    for c, p in zip(outs["continuous"], probs):
        one = entropic_gw(*p, solver)
        assert int(c.info.outer_iters) == int(one.info.outer_iters)
        assert int(c.info.inner_iters) == int(one.info.inner_iters)
        np.testing.assert_allclose(np.asarray(c.plan), np.asarray(one.plan),
                                   rtol=1e-10, atol=1e-12)


def test_serve_config_backend_override():
    """`GWServeConfig.sinkhorn_backend` overrides the solver cfg at flush
    resolution — and only then (None keeps the solver's own knob)."""
    solver = GWConfig(sinkhorn_backend="xla")
    assert GWServeConfig(solver=solver).solver_cfg().sinkhorn_backend == "xla"
    assert (GWServeConfig(solver=solver, sinkhorn_backend="pallas")
            .solver_cfg().sinkhorn_backend == "pallas")
    # the default solver cfg advertises auto-resolution
    assert GWConfig().sinkhorn_backend == "auto"


def test_kernel_cache_bounded_across_serving_stream():
    """A mixed-ε serving stream through the pallas backend compiles each
    kernel once per (shape, batch-width) — ε and tolerances ride as traced
    operands (the kernel-level twin of the engine's bounded-jit-cache
    guarantee)."""
    row = sinkhorn_step.sinkhorn_row_update_pallas
    col = sinkhorn_step.sinkhorn_col_update_pallas
    row.clear_cache()
    col.clear_cache()
    solver = GWConfig(eps=1e-2, outer_iters=6, sinkhorn_iters=40, tol=1e-5,
                      sinkhorn_backend="pallas")
    eng = GWEngine(GWServeConfig(solver=solver, max_batch=4, size_bucket=32,
                                 segment_iters=3))
    for i, (s, eps) in enumerate([(20, 1e-2), (25, 5e-2), (30, 2e-2),
                                  (28, 1e-2)]):
        eng.submit(*_grid_problem(s, s, 61 + i), eps=eps)
    res = eng.flush()
    assert len(res) == 4
    # one padded shape bucket (32×32) × ≤ log2(4)+1 batch widths
    assert row._cache_size() <= 3
    assert col._cache_size() <= 3
