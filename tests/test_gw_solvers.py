"""Solver-level reproduction of the paper's claims: FGC == dense plans
(Tables 2-6 column ‖P_Fa − P‖_F), invariances (§4.4.1), variants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FGWConfig, GWConfig, UGWConfig, entropic_fgw,
                        entropic_gw, entropic_ugw, gw_energy)
from repro.core.grids import Grid1D, Grid2D

RNG = np.random.default_rng(7)


def _measures(n, seed):
    r = np.random.default_rng(seed)
    u = r.random(n) + 0.05
    return jnp.asarray(u / u.sum())


@pytest.mark.parametrize("backend", ["scan", "cumsum", "pallas"])
@pytest.mark.parametrize("k", [1, 2])
def test_fgc_matches_dense_1d(backend, k):
    """Paper Table 2: FGC plans equal the original entropic GW plans to
    machine precision."""
    n = 50
    gx, gy = Grid1D(n, 1 / (n - 1), k), Grid1D(n, 1 / (n - 1), k)
    mu, nu = _measures(n, 0), _measures(n, 1)
    cfg = dict(eps=2e-3, outer_iters=10, sinkhorn_iters=200)
    rf = entropic_gw(gx, gy, mu, nu, GWConfig(backend=backend, **cfg))
    rd = entropic_gw(gx, gy, mu, nu, GWConfig(backend="dense", **cfg))
    assert float(jnp.linalg.norm(rf.plan - rd.plan)) < 1e-12
    assert abs(float(rf.value - rd.value)) < 1e-12


def test_fgc_matches_dense_2d():
    """Paper Table 3 (2D random distributions)."""
    n = 6
    gx, gy = Grid2D(n, 1 / (n - 1), 1), Grid2D(n, 1 / (n - 1), 1)
    mu, nu = _measures(n * n, 2), _measures(n * n, 3)
    cfg = dict(eps=4e-3, outer_iters=8, sinkhorn_iters=150)
    rf = entropic_gw(gx, gy, mu, nu, GWConfig(backend="cumsum", **cfg))
    rd = entropic_gw(gx, gy, mu, nu, GWConfig(backend="dense", **cfg))
    assert float(jnp.linalg.norm(rf.plan - rd.plan)) < 1e-11


def test_fgw_matches_dense():
    """Paper Table 2 FGW rows (θ=0.5, c_ip=|i−p|)."""
    n = 40
    gx, gy = Grid1D(n, 1 / (n - 1), 1), Grid1D(n, 1 / (n - 1), 1)
    mu, nu = _measures(n, 4), _measures(n, 5)
    c = jnp.abs(jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) \
        .astype(jnp.float64) / (n - 1)
    cfg = dict(eps=2e-3, outer_iters=10, sinkhorn_iters=200, theta=0.5)
    rf = entropic_fgw(gx, gy, c, mu, nu, FGWConfig(backend="cumsum", **cfg))
    rd = entropic_fgw(gx, gy, c, mu, nu, FGWConfig(backend="dense", **cfg))
    assert float(jnp.linalg.norm(rf.plan - rd.plan)) < 1e-12


def test_ugw_matches_dense():
    """Remark 2.3: FGC applies to the unbalanced variant unchanged."""
    n = 30
    gx, gy = Grid1D(n, 1 / (n - 1), 1), Grid1D(n, 1 / (n - 1), 1)
    mu, nu = _measures(n, 6), _measures(n, 7)
    cfg = dict(eps=1e-2, rho=1.0, outer_iters=6, sinkhorn_iters=150)
    rf = entropic_ugw(gx, gy, mu, nu, UGWConfig(backend="cumsum", **cfg))
    rd = entropic_ugw(gx, gy, mu, nu, UGWConfig(backend="dense", **cfg))
    assert float(jnp.linalg.norm(rf.plan - rd.plan)) < 1e-10
    assert np.isfinite(float(rf.value))


def test_gw_reflection_invariance():
    """GW is invariant to isometries (reflection of one measure); the FGC
    path must preserve this exactly (paper §4.4.1)."""
    n = 40
    gx = Grid1D(n, 1 / (n - 1), 1)
    mu, nu = _measures(n, 8), _measures(n, 9)
    cfg = GWConfig(eps=2e-3, outer_iters=10, sinkhorn_iters=300,
                   backend="cumsum")
    v1 = entropic_gw(gx, gx, mu, nu, cfg).value
    v2 = entropic_gw(gx, gx, mu, nu[::-1], cfg).value
    assert abs(float(v1 - v2)) < 1e-8


def test_gw_self_distance_near_zero():
    n = 30
    gx = Grid1D(n, 1 / (n - 1), 1)
    mu = _measures(n, 10)
    res = entropic_gw(gx, gx, mu, mu,
                      GWConfig(eps=1e-3, outer_iters=15,
                               sinkhorn_iters=400, backend="cumsum"))
    # entropic bias keeps it positive but it must be tiny
    assert float(res.value) < 1e-2


def test_gw_energy_definition():
    """gw_energy must equal the brute-force quadruple sum."""
    m, n = 8, 9
    gx, gy = Grid1D(m, 0.3, 1), Grid1D(n, 0.2, 2)
    gamma = jnp.asarray(RNG.random((m, n)))
    dx = np.asarray(gx.dist_matrix())
    dy = np.asarray(gy.dist_matrix())
    g = np.asarray(gamma)
    brute = sum((dx[i, j] - dy[p, q]) ** 2 * g[i, p] * g[j, q]
                for i in range(m) for j in range(m)
                for p in range(n) for q in range(n))
    fast = float(gw_energy(gx, gy, gamma, backend="cumsum"))
    np.testing.assert_allclose(fast, brute, rtol=1e-10)
