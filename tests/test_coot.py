"""Co-Optimal Transport extension (paper §5 conclusion)."""
import jax.numpy as jnp
import numpy as np

from repro.core import coot
from repro.core.grids import Grid1D

RNG = np.random.default_rng(41)


def _uniform(n):
    return jnp.full((n,), 1.0 / n, jnp.float64)


def test_coot_self_alignment_near_identity():
    """COOT(X, X) with distinct rows/cols should recover identity-ish
    plans on both the sample and feature sides."""
    x = jnp.asarray(RNG.normal(size=(12, 8)) * 2.0)
    cfg = coot.COOTConfig(eps_samples=5e-3, eps_features=5e-3,
                          outer_iters=12, sinkhorn_iters=200)
    pi_s, pi_v, val = coot.entropic_coot(
        x, x, _uniform(12), _uniform(12), _uniform(8), _uniform(8), cfg)
    assert (np.argmax(np.asarray(pi_s), 1) == np.arange(12)).mean() > 0.8
    assert (np.argmax(np.asarray(pi_v), 1) == np.arange(8)).mean() > 0.7
    assert float(val) < 0.5


def test_coot_marginals_and_value_finite():
    x = jnp.asarray(RNG.normal(size=(10, 6)))
    y = jnp.asarray(RNG.normal(size=(14, 9)))
    pi_s, pi_v, val = coot.entropic_coot(
        x, y, _uniform(10), _uniform(14), _uniform(6), _uniform(9),
        coot.COOTConfig(outer_iters=6, sinkhorn_iters=150))
    np.testing.assert_allclose(np.asarray(pi_s.sum(1)), 1 / 10, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pi_v.sum(0)), 1 / 9, atol=1e-5)
    assert np.isfinite(float(val))


def test_coot_gw_specialization_fgc_matches_dense():
    """When X, Y are grid distance matrices, the FGC product path must give
    the same plans as the dense path (the paper's conclusion claim)."""
    n, m = 20, 25
    gx, gy = Grid1D(n, 1 / (n - 1), 1), Grid1D(m, 1 / (m - 1), 1)
    x = gx.dist_matrix()
    y = gy.dist_matrix()
    args = (x, y, _uniform(n), _uniform(m), _uniform(n), _uniform(m))
    cfg = coot.COOTConfig(outer_iters=6, sinkhorn_iters=150)
    ps_f, pv_f, v_f = coot.entropic_coot(*args, cfg, grid_x=gx, grid_y=gy)
    ps_d, pv_d, v_d = coot.entropic_coot(*args, cfg)
    # the per-iteration product parity is ~1e-16 (tested in isolation);
    # BCD amplifies the residual through 6 alternations — 1e-5 plan /
    # 1e-8 value reflects that, still far inside solver tolerance
    assert float(jnp.linalg.norm(ps_f - ps_d)) < 1e-5
    assert abs(float(v_f - v_d)) < 1e-8
    from repro.core.gradient import bilinear_product
    pv = args[2][:, None] * args[3][None, :] * 0 + \
        args[4].sum() * args[2][:, None] * args[3][None, :]
    b1 = bilinear_product(x, pv, y, gx, gy, "cumsum")
    b2 = bilinear_product(x, pv, y, None, None, "cumsum")
    assert float(jnp.max(jnp.abs(b1 - b2))) < 1e-12
