"""Test config: x64 for solver precision (paper validates to ~1e-15).

NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
smoke tests and benches must see the real single device; only
launch/dryrun.py (a subprocess in tests) requests 512 host devices.
"""
import jax

jax.config.update("jax_enable_x64", True)
