"""Batched GW engine: entropic_gw_batch == a loop of entropic_gw on ragged
padded inputs; GWEngine bucketing; GradientOperator is the single gradient
home for all solvers."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GradientOperator, GWConfig, entropic_gw,
                        entropic_gw_batch)
from repro.core.grids import Grid1D, Grid2D
from repro.serve.engine import GWEngine, GWServeConfig

CFG = GWConfig(eps=2e-3, outer_iters=6, sinkhorn_iters=120, backend="cumsum")


def _measures(n, seed):
    r = np.random.default_rng(seed)
    u = r.random(n) + 0.05
    return jnp.asarray(u / u.sum())


def _problems_1d(sizes, k=1):
    out = []
    for i, (m, n) in enumerate(sizes):
        out.append((Grid1D(m, 1 / (m - 1), k), Grid1D(n, 1 / (n - 1), k),
                    _measures(m, 2 * i), _measures(n, 2 * i + 1)))
    return out


def test_batch_matches_loop_ragged():
    """One vmapped padded solve == per-problem solves, exactly (zero-mass
    padding is inert under log-domain Sinkhorn)."""
    probs = _problems_1d([(30, 30), (25, 40), (40, 33), (17, 22)])
    batch = entropic_gw_batch(probs, CFG)
    for res, (gx, gy, mu, nu) in zip(batch, probs):
        single = entropic_gw(gx, gy, mu, nu, CFG)
        assert res.plan.shape == (gx.size, gy.size)
        np.testing.assert_allclose(np.asarray(res.plan),
                                   np.asarray(single.plan), atol=1e-10)
        assert abs(float(res.value - single.value)) < 1e-10
        assert np.isfinite(np.asarray(res.plan)).all()


def test_batch_explicit_pad_to():
    """Serving buckets: pad beyond the max size must not change results."""
    probs = _problems_1d([(20, 25), (24, 30)])
    plain = entropic_gw_batch(probs, CFG)
    padded = entropic_gw_batch(probs, CFG, pad_to=(64, 64))
    for a, b in zip(plain, padded):
        # padding changes the cumsum length/centering, whose f64 roundoff is
        # amplified ~1/eps per Sinkhorn solve — identical only in exact
        # arithmetic; observed ~3e-8 against plan entries of O(5e-2).
        np.testing.assert_allclose(np.asarray(a.plan), np.asarray(b.plan),
                                   atol=1e-6)


def test_batch_varying_spacing():
    """h is traced per-problem: grids may differ in spacing inside a batch."""
    probs = [(Grid1D(20, 0.05, 1), Grid1D(20, 0.02, 1),
              _measures(20, 0), _measures(20, 1)),
             (Grid1D(20, 0.10, 1), Grid1D(20, 0.03, 1),
              _measures(20, 2), _measures(20, 3))]
    batch = entropic_gw_batch(probs, CFG)
    for res, (gx, gy, mu, nu) in zip(batch, probs):
        single = entropic_gw(gx, gy, mu, nu, CFG)
        np.testing.assert_allclose(np.asarray(res.plan),
                                   np.asarray(single.plan), atol=1e-10)


def test_batch_grid2d_equal_sizes():
    n = 5
    cfg = GWConfig(eps=4e-3, outer_iters=4, sinkhorn_iters=80,
                   backend="cumsum")
    probs = [(Grid2D(n, 1 / (n - 1), 1), Grid2D(n, 1 / (n - 1), 1),
              _measures(n * n, s), _measures(n * n, s + 10))
             for s in range(3)]
    batch = entropic_gw_batch(probs, cfg)
    for res, (gx, gy, mu, nu) in zip(batch, probs):
        single = entropic_gw(gx, gy, mu, nu, cfg)
        np.testing.assert_allclose(np.asarray(res.plan),
                                   np.asarray(single.plan), atol=1e-10)


def test_batch_rejects_mixed_k():
    probs = _problems_1d([(10, 10)], k=1) + _problems_1d([(10, 10)], k=2)
    with pytest.raises(ValueError):
        entropic_gw_batch(probs, CFG)


def test_batch_empty():
    assert entropic_gw_batch([], CFG) == []


def test_engine_flush_matches_single():
    scfg = GWServeConfig(solver=CFG, max_batch=3, size_bucket=32)
    eng = GWEngine(scfg)
    probs = _problems_1d([(20, 25), (30, 18), (25, 25), (50, 40), (12, 12)])
    rids = [eng.submit(*p) for p in probs]
    out = eng.flush()
    assert set(out) == set(rids)
    for rid, (gx, gy, mu, nu) in zip(rids, probs):
        ref = entropic_gw(gx, gy, mu, nu, CFG)
        assert out[rid].plan.shape == (gx.size, gy.size)
        np.testing.assert_allclose(np.asarray(out[rid].plan),
                                   np.asarray(ref.plan), atol=1e-8)
    assert eng.flush() == {}       # queue drained


def test_engine_rejects_malformed_request_at_submit():
    """A data-independent malformation (measure length != geometry size) is
    rejected at submit() — once queued it would fail its whole bucket on
    every flush and starve the valid requests chunked with it."""
    eng = GWEngine(GWServeConfig(solver=CFG, size_bucket=16))
    gx = Grid1D(5, 0.1, 1)
    with pytest.raises(ValueError):
        eng.submit(gx, gx, _measures(20, 0), _measures(5, 1))  # mu too long
    assert eng._queue == []
    # batch-level validation also rejects it (direct entropic_gw_batch use)
    with pytest.raises(ValueError):
        entropic_gw_batch([(gx, gx, _measures(20, 0), _measures(5, 1))], CFG)


def test_engine_partial_failure_isolates_bucket(monkeypatch):
    """A bucket that raises at flush time leaves its requests queued for
    retry and records the error; other buckets still return their results.
    (Barrier scheduler — the continuous scheduler's failure isolation is
    covered in test_serve_continuous.py.)"""
    from repro.serve import engine as engine_mod

    eng = GWEngine(GWServeConfig(solver=CFG, size_bucket=16,
                                 scheduler="barrier"))
    good = _problems_1d([(10, 12), (14, 9)])
    good_rids = [eng.submit(*p) for p in good]
    bad_grid = Grid1D(40, 0.1, 1)        # lands in a different size bucket
    bad_rid = eng.submit(bad_grid, bad_grid,
                         _measures(40, 0), _measures(40, 1))

    real_batch = engine_mod.entropic_gw_batch

    def failing_batch(probs, cfg, pad_to=None, **kw):
        if pad_to and pad_to[0] >= 48:   # only the bad-request bucket
            raise RuntimeError("injected bucket failure")
        return real_batch(probs, cfg, pad_to=pad_to, **kw)

    monkeypatch.setattr(engine_mod, "entropic_gw_batch", failing_batch)
    out = eng.flush()                     # must NOT raise: good bucket solved
    assert set(out) == set(good_rids)
    for rid, (gx, gy, mu, nu) in zip(good_rids, good):
        ref = entropic_gw(gx, gy, mu, nu, CFG)
        np.testing.assert_allclose(np.asarray(out[rid].plan),
                                   np.asarray(ref.plan), atol=1e-8)
    # failed bucket: request still queued, error recorded
    assert [r.rid for r in eng._queue] == [bad_rid]
    assert len(eng.last_errors) == 1
    assert isinstance(eng.last_errors[0][1], RuntimeError)
    # a retry with nothing else queued surfaces the error
    with pytest.raises(RuntimeError):
        eng.flush()
    assert [r.rid for r in eng._queue] == [bad_rid]
    # once the fault clears, the queued request finally solves
    monkeypatch.setattr(engine_mod, "entropic_gw_batch", real_batch)
    out2 = eng.flush()
    assert set(out2) == {bad_rid} and eng._queue == []


def test_engine_mixed_grid_pointcloud_queue():
    """Grids and point clouds interleave in one queue; bucketing splits them
    by geometry spec and every request is solved correctly."""
    from repro.core.geometry import PointCloudGeometry

    eng = GWEngine(GWServeConfig(solver=CFG, max_batch=4, size_bucket=32))
    rng = np.random.default_rng(7)
    probs = {}
    for i, (m, n) in enumerate([(20, 25), (30, 18), (25, 25)]):
        p = (Grid1D(m, 1 / (m - 1), 1), Grid1D(n, 1 / (n - 1), 1),
             _measures(m, 2 * i), _measures(n, 2 * i + 1))
        probs[eng.submit(*p)] = p
    for i, n in enumerate([22, 17, 28]):
        pc = PointCloudGeometry(jnp.asarray(rng.normal(size=(n, 2))))
        p = (pc, pc, _measures(n, 50 + i), _measures(n, 60 + i))
        probs[eng.submit(*p)] = p
    # two distinct geometry buckets
    keys = {eng._bucket_key(r) for r in eng._queue}
    assert len(keys) == 2
    out = eng.flush()
    assert set(out) == set(probs)
    for rid, (gx, gy, mu, nu) in probs.items():
        ref = entropic_gw(gx, gy, mu, nu, CFG)
        assert out[rid].plan.shape == (np.asarray(mu).size,
                                       np.asarray(nu).size)
        np.testing.assert_allclose(np.asarray(out[rid].plan),
                                   np.asarray(ref.plan), atol=1e-8)
    assert eng.flush() == {}


def test_gradient_operator_matches_dense():
    """The shared operator's FGC path == dense path for every piece."""
    m, n = 18, 23
    gx, gy = Grid1D(m, 0.3, 1), Grid1D(n, 0.2, 2)
    mu, nu = _measures(m, 5), _measures(n, 6)
    gamma = jnp.asarray(np.random.default_rng(0).random((m, n)))
    fast = GradientOperator(gx, gy, "cumsum")
    dense = GradientOperator(gx, gy, "dense")
    np.testing.assert_allclose(np.asarray(fast.product(gamma)),
                               np.asarray(dense.product(gamma)), atol=1e-9)
    c_f, dx_f, dy_f = fast.constant_term(mu, nu)
    c_d, dx_d, dy_d = dense.constant_term(mu, nu)
    np.testing.assert_allclose(np.asarray(c_f), np.asarray(c_d), atol=1e-9)
    np.testing.assert_allclose(float(fast.energy(gamma)),
                               float(dense.energy(gamma)), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(fast.grad(gamma, c_f)),
                               np.asarray(dense.grad(gamma, c_d)), atol=1e-8)
