"""Optimizer, schedules, compression, data pipeline, checkpointing,
fault tolerance — the substrate layers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.distributed.fault_tolerance import (Heartbeat, StragglerDetector,
                                               run_with_restarts)
from repro.train import optimizer as optim


# -- optimizer --------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    cfg = optim.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, grad_clip=1e9)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = optim.init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = optim.apply_updates(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_lr_schedule_shape():
    cfg = optim.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
    lrs = [float(optim.lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = optim.clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0)


def test_int8_compression_error_feedback():
    """Error feedback must keep the long-run average unbiased: the summed
    compressed updates converge to the summed true gradients."""
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 1e-3)
    ef = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        out, ef = optim.compress_decompress({"g": g}, {"g": ef})
        out, ef = out["g"], {"g": ef["g"]}["g"]
        total = total + out
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=2e-6)


def test_quantize_int8_range():
    q, scale = optim.quantize_int8(jnp.asarray([-1.0, 0.5, 1.0]))
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q))) <= 127


# -- data -------------------------------------------------------------------

def test_synthetic_determinism():
    cfg = pipeline.DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    ds = pipeline.SyntheticLM(cfg)
    b1, b2 = ds.batch(7), ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are the shifted stream
    assert b1["tokens"].shape == b1["labels"].shape == (4, 16)


def test_host_slicing_disjoint_union():
    full = pipeline.SyntheticLM(pipeline.DataConfig(
        vocab_size=50, seq_len=8, global_batch=8)).batch(3)
    parts = [pipeline.SyntheticLM(pipeline.DataConfig(
        vocab_size=50, seq_len=8, global_batch=8, num_hosts=4,
        host_id=h)).batch(3) for h in range(4)]
    merged = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(merged, full["tokens"])


def test_memmap_dataset(tmp_path):
    toks = np.arange(10_000) % 313
    path = str(tmp_path / "tokens.bin")
    pipeline.write_token_file(path, toks)
    ds = pipeline.MemmapLM(pipeline.DataConfig(
        vocab_size=313, seq_len=32, global_batch=2, kind="memmap",
        path=path))
    b = ds.batch(0)
    assert b["tokens"].shape == (2, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# -- checkpoint -------------------------------------------------------------

def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(4, 3))),
            "nested": {"b": jnp.asarray(r.normal(size=(7,))),
                       "step": jnp.asarray(5, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(10, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = mgr.restore(like)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), out, tree)


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [2, 3]
    assert mgr.latest_step() == 3


def test_checkpoint_ignores_incomplete_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    os.makedirs(str(tmp_path / "step_00000009.tmp"))  # crashed save
    assert mgr.latest_step() == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(4, _tree(4))
    mgr.wait()
    assert mgr.latest_step() == 4


# -- fault tolerance ---------------------------------------------------------

def test_run_with_restarts_recovers(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(0, _tree())
    calls = {"n": 0}

    def train_fn(resume):
        calls["n"] += 1
        if calls["n"] == 1:
            mgr.save(5, _tree(5))
            raise RuntimeError("simulated node failure")
        assert resume == 5  # resumed from the crash checkpoint
        return 10

    final, restarts = run_with_restarts(train_fn, mgr, max_restarts=2)
    assert final == 10 and restarts == 1


def test_run_with_restarts_gives_up(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)

    def always_fail(resume):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        run_with_restarts(always_fail, mgr, max_restarts=1)


def test_heartbeat_stale_detection(tmp_path):
    d = str(tmp_path)
    hb0 = Heartbeat(d, 0)
    hb1 = Heartbeat(d, 1)
    hb0.beat(1, t=1000.0)
    hb1.beat(1, t=1100.0)
    assert Heartbeat.stale_hosts(d, timeout_s=60, now=1130.0) == [0]
    assert Heartbeat.stale_hosts(d, timeout_s=200, now=1130.0) == []


def test_straggler_detector():
    det = StragglerDetector(k=3.0, min_samples=4)
    for h in range(6):
        det.record(h, 1.0 + 0.01 * h)
    det.record(6, 30.0)
    assert det.stragglers() == [6]
