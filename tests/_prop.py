"""Property-test shim: real `hypothesis` when installed, deterministic
parametrized fallback when not.

The container policy forbids installing packages, so test modules import

    from _prop import given, settings, st

instead of `from hypothesis import ...`.  With hypothesis present these are
the genuine articles (full shrinking/fuzzing).  Without it, `st.integers`
returns a range description and `given` expands into a fixed
`pytest.mark.parametrize` sweep of `FALLBACK_EXAMPLES` draws from a seeded
RNG — deterministic, so failures are reproducible, and the suite always
collects.
"""
from __future__ import annotations

import random

import pytest

FALLBACK_EXAMPLES = 10

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _IntRange:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def draw(self, rng: random.Random) -> int:
            return rng.randint(self.lo, self.hi)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntRange:
            return _IntRange(min_value, max_value)

    st = _Strategies()

    def settings(*_args, **_kwargs):
        """No-op stand-in for hypothesis.settings(...)."""
        return lambda fn: fn

    def given(**strategies):
        """Deterministic sweep: the first draw is every range's low end
        (hypothesis-style boundary case), the rest are seeded-random."""
        names = sorted(strategies)

        def deco(fn):
            rng = random.Random(0xF6C)
            cases = [tuple(strategies[n].lo for n in names)]
            cases += [tuple(strategies[n].draw(rng) for n in names)
                      for _ in range(FALLBACK_EXAMPLES - 1)]
            if len(names) == 1:
                # parametrize with one argname takes scalars, not 1-tuples
                cases = [c[0] for c in cases]
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
