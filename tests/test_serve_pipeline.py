"""Property suite for the pipelined multi-bucket scheduler.

The pipeline scheduler overlaps one bucket's host-side harvest/refill with
other buckets' device compute (async dispatch, donated carries).  The
contract it must keep:

  (a) every request id returned exactly once, however the stream buckets;
  (b) results BIT-identical to the barrier and continuous schedulers and
      matching the unbatched solver — pipelining changes wall-clock
      overlap only, never any lane's iterates (each bucket still walks the
      same serial issue→harvest sequence; only the interleaving ACROSS
      buckets changes, and buckets share no state).  The bitwise claim is
      per-executable: carry DONATION compiles a twin executable whose
      aliased buffers may reorder a reduction's last ulp, so the donated
      path is pinned to 1e-12 with exact iteration counts instead;
  (c) telemetry reflects real overlap: with ≥2 buckets in flight the
      dispatch-depth histogram must record depth ≥ 2;
  (d) per-bucket failure isolation — a poisoned bucket's error is recorded
      and its requests requeued while other buckets' results still land;
  (e) the standing event loop (`serve` / `run_event_loop`) is a scheduling
      shell over the same lanes: it returns the flush results bit-for-bit.
"""
import dataclasses
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _prop import given, settings, st

from repro.core import GWConfig, SolveControls, entropic_gw
from repro.core.geometry import as_geometry
from repro.core.grids import Grid1D
from repro.serve import engine as engine_mod
from repro.serve.engine import (GWEngine, GWServeConfig, run_event_loop)
from test_serve_continuous import (SOLVER, TOL, _controls, _measures,
                                   _problem)


def _mk(sched: str, **kw) -> GWEngine:
    kw.setdefault("max_batch", 4)
    return GWEngine(GWServeConfig(
        solver=SOLVER, size_bucket=16, tol=TOL,
        scheduler=sched, segment_iters=3, **kw))


def _mixed_stream(n: int, base_seed: int):
    """n problems cycling over grid / point-cloud / low-rank geometries —
    three distinct buckets, so the pipeline has cross-bucket overlap to
    exploit."""
    out = []
    for i in range(n):
        s = base_seed + i
        out.append((_problem(i % 3, s), _controls(s)))
    return out


def _assert_same_result(a, b):
    """Plans/couplings the SAME BITS; the scalar energy to reduction
    roundoff (the padded-batch contraction order differs between slot
    widths, so the last ulps of the float64 sum may not)."""
    if a.plan is not None or b.plan is not None:
        np.testing.assert_array_equal(np.asarray(a.plan), np.asarray(b.plan))
    else:
        for la, lb in zip(jax.tree_util.tree_leaves(a.coupling),
                          jax.tree_util.tree_leaves(b.coupling)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_allclose(float(a.value), float(b.value),
                               rtol=1e-12, atol=1e-15)
    assert int(a.info.outer_iters) == int(b.info.outer_iters)
    assert int(a.info.inner_iters) == int(b.info.inner_iters)


# ---------------------------------------------------------------------------
# (a) + (b): pipeline == barrier == continuous, bit for bit
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None, derandomize=True)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_pipeline_ids_once_and_identical_to_other_schedulers(seed):
    # donation off for the BITWISE claim: the donated dispatch is its own
    # XLA executable, whose aliased buffers may reorder a reduction's last
    # ulp (that twin is pinned to 1e-12 in the donation test below)
    rng = np.random.default_rng(seed)
    pipe = _mk("pipeline", donate_carries=False)
    cont, barr = _mk("continuous"), _mk("barrier")
    expect: dict[int, tuple] = {}
    got: dict[int, object] = {}

    def do_flush():
        out_p, out_c, out_b = pipe.flush(), cont.flush(), barr.flush()
        assert set(out_p) == set(out_c) == set(out_b)
        for rid, res in out_p.items():
            assert rid not in got, f"request {rid} returned twice"
            got[rid] = res
            _assert_same_result(res, out_c[rid])
            _assert_same_result(res, out_b[rid])

    for _ in range(int(rng.integers(4, 9))):
        if expect and rng.random() < 0.3:
            do_flush()
        else:
            kind = int(rng.integers(0, 3))
            s = int(rng.integers(0, 10 ** 8))
            prob, ctl = _problem(kind, s), _controls(s)
            rid = pipe.submit(*prob, controls=ctl)
            assert cont.submit(*prob, controls=ctl) == rid
            assert barr.submit(*prob, controls=ctl) == rid
            expect[rid] = (prob, ctl)
    do_flush()
    do_flush()          # drained queue: nothing returned twice
    assert sorted(got) == sorted(expect)

    # spot-check one lane against the truly unbatched solver
    rid = sorted(got)[int(rng.integers(len(got)))]
    prob, ctl = expect[rid]
    ref = entropic_gw(*prob, SOLVER, controls=ctl)
    if got[rid].plan is not None:
        np.testing.assert_allclose(np.asarray(got[rid].plan),
                                   np.asarray(ref.plan), atol=1e-10)
    assert int(got[rid].info.outer_iters) == int(ref.info.outer_iters)


def test_pipeline_no_donation_is_bitwise_with_continuous():
    """With donation off the pipeline runs the very same executable as the
    continuous scheduler — its per-bucket iterates must be the SAME BITS."""
    pipe = _mk("pipeline", donate_carries=False)
    cont = _mk("continuous")
    reqs = {}
    for prob, ctl in _mixed_stream(5, 9000):
        rid = pipe.submit(*prob, controls=ctl)
        assert cont.submit(*prob, controls=ctl) == rid
        reqs[rid] = prob
    out_p, out_c = pipe.flush(), cont.flush()
    assert set(out_p) == set(out_c) == set(reqs)
    for rid in reqs:
        _assert_same_result(out_p[rid], out_c[rid])


def test_pipeline_donation_matches_to_reduction_roundoff():
    """Donation routes dispatches through a SEPARATE XLA executable whose
    buffer aliasing may reorder a reduction's last ulp — so the contract is
    iteration-counts EXACT and plans to 1e-12, not bitwise."""
    don = _mk("pipeline", donate_carries=True)
    ref = _mk("pipeline", donate_carries=False)
    reqs = {}
    for prob, ctl in _mixed_stream(5, 9100):
        rid = don.submit(*prob, controls=ctl)
        assert ref.submit(*prob, controls=ctl) == rid
        reqs[rid] = prob
    out_d, out_r = don.flush(), ref.flush()
    assert set(out_d) == set(out_r) == set(reqs)
    for rid in reqs:
        a, b = out_d[rid], out_r[rid]
        if a.plan is not None:
            np.testing.assert_allclose(np.asarray(a.plan),
                                       np.asarray(b.plan),
                                       rtol=0, atol=1e-12)
        else:
            for la, lb in zip(jax.tree_util.tree_leaves(a.coupling),
                              jax.tree_util.tree_leaves(b.coupling)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=0, atol=1e-10)
        assert int(a.info.outer_iters) == int(b.info.outer_iters)
        assert int(a.info.inner_iters) == int(b.info.inner_iters)


# ---------------------------------------------------------------------------
# (c) pipeline telemetry: real cross-bucket overlap, wall-time accounting
# ---------------------------------------------------------------------------

def test_pipeline_telemetry_records_overlap():
    pipe = _mk("pipeline", max_inflight_buckets=2)
    for prob, ctl in _mixed_stream(6, 4000):
        pipe.submit(*prob, controls=ctl)
    out = pipe.flush()
    assert len(out) == 6
    s = pipe.stats
    assert s["dispatches"] > 0
    assert s["flush_wall_s"] > 0.0
    assert s["device_idle_s"] >= 0.0
    assert s["device_idle_s"] <= s["flush_wall_s"]
    # the histogram counts every dispatch, at the depth it entered flight
    assert sum(s["dispatch_depth"].values()) == s["dispatches"]
    # ≥2 buckets in the stream and depth 2 allowed → real overlap happened
    assert max(s["dispatch_depth"]) >= 2


def test_pipeline_depth_one_degrades_to_serial():
    """max_inflight_buckets=1 is the serial continuous scheduler with a
    different harvest order — never more than one dispatch in flight."""
    pipe = _mk("pipeline", max_inflight_buckets=1)
    cont = _mk("continuous")
    reqs = []
    for prob, ctl in _mixed_stream(4, 4100):
        rid = pipe.submit(*prob, controls=ctl)
        assert cont.submit(*prob, controls=ctl) == rid
        reqs.append(rid)
    out_p, out_c = pipe.flush(), cont.flush()
    assert max(pipe.stats["dispatch_depth"]) == 1
    for rid in reqs:
        _assert_same_result(out_p[rid], out_c[rid])


# ---------------------------------------------------------------------------
# (d) per-bucket failure isolation under the pipeline
# ---------------------------------------------------------------------------

def test_pipeline_bucket_failure_isolates_and_requeues(monkeypatch):
    eng = _mk("pipeline", max_inflight_buckets=2)
    good = []
    for i in range(2):
        p = _problem(0, 50 + i)           # sizes ≤ 16 → pad-16 bucket
        good.append((eng.submit(*p, controls=_controls(50 + i)), p))
    big = Grid1D(24, 1 / 23, 1)           # its own pad-24 bucket
    pb = (as_geometry(big, SOLVER.backend), as_geometry(big, SOLVER.backend),
          _measures(24, 90), _measures(24, 91))
    ctl_b = SolveControls.make(8e-3, TOL, 5e-2, 0.5)
    bad_rid = eng.submit(*pb, controls=ctl_b)

    real = engine_mod._segment_stacked_donated
    calls = {"n": 0}

    def failing(gx, gy, mus, nus, feats, ctls, carry, cfg, segment):
        if mus.shape[1] >= 24:            # only the big bucket
            calls["n"] += 1
            if calls["n"] >= 2:           # fail on its SECOND dispatch
                raise RuntimeError("injected mid-solve failure")
        return real(gx, gy, mus, nus, feats, ctls, carry, cfg, segment)

    monkeypatch.setattr(engine_mod, "_segment_stacked_donated", failing)
    out = eng.flush()                     # must NOT raise: good bucket ok
    assert set(out) == {r for r, _ in good}
    for rid, _ in good:
        assert bool(out[rid].info.converged)
    assert [r.rid for r in eng._queue] == [bad_rid]
    assert len(eng.last_errors) == 1
    assert isinstance(eng.last_errors[0][1], RuntimeError)
    # fault clears → the requeued request solves exactly
    monkeypatch.setattr(engine_mod, "_segment_stacked_donated", real)
    out2 = eng.flush()
    assert set(out2) == {bad_rid} and eng._queue == []
    ref = entropic_gw(*pb, SOLVER, controls=ctl_b)
    np.testing.assert_allclose(np.asarray(out2[bad_rid].plan),
                               np.asarray(ref.plan), atol=1e-10)
    assert (int(out2[bad_rid].info.outer_iters)
            == int(ref.info.outer_iters))


# ---------------------------------------------------------------------------
# (e) the standing event loop is a scheduling shell over the same lanes
# ---------------------------------------------------------------------------

def test_event_loop_matches_flush():
    """The standing loop admits incrementally, so its buckets may run at
    different slot widths than a one-shot flush — results must match to
    the width-crossing contract the repo holds everywhere (plans to
    padding roundoff, iteration counts EXACTLY)."""
    stream = _mixed_stream(6, 7000)
    cont = _mk("continuous")
    expect = {}
    for prob, ctl in stream:
        expect[cont.submit(*prob, controls=ctl)] = prob
    ref = cont.flush()

    served = _mk("pipeline", max_inflight_buckets=2)
    source = [((*prob,), {"controls": ctl}) for prob, ctl in stream]
    seen = []
    got = run_event_loop(served, source,
                         on_result=lambda rid, res: seen.append(rid))
    assert sorted(got) == sorted(expect) == sorted(seen)
    assert len(seen) == len(set(seen))    # each rid yielded exactly once
    for rid in got:
        a, b = got[rid], ref[rid]
        if a.plan is not None:
            np.testing.assert_allclose(np.asarray(a.plan),
                                       np.asarray(b.plan), atol=1e-10)
        else:
            for la, lb in zip(jax.tree_util.tree_leaves(a.coupling),
                              jax.tree_util.tree_leaves(b.coupling)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           atol=1e-8)
        assert int(a.info.outer_iters) == int(b.info.outer_iters)
        assert int(a.info.inner_iters) == int(b.info.inner_iters)


def test_event_loop_handles_lazy_source():
    """Admission pulls from a generator as capacity frees up — the loop
    must terminate with every request answered even when the source is
    produced lazily and slower than the solver drains it."""
    def source():
        for prob, ctl in _mixed_stream(5, 7500):
            yield ((*prob,), {"controls": ctl})

    eng = _mk("pipeline", max_inflight_buckets=2, max_batch=2)
    got = run_event_loop(eng, source())
    assert sorted(got) == list(range(5))
    for res in got.values():       # every lane ran to a terminal state
        assert (bool(res.info.converged)
                or int(res.info.outer_iters) >= SOLVER.outer_iters)


# ---------------------------------------------------------------------------
# cache-aware admission ordering
# ---------------------------------------------------------------------------

def test_warm_start_hardness_near_zero():
    """A request holding a cached warm start must rank far below the cold
    solve its knobs would suggest — repeat traffic never starves behind
    fresh hard problems."""
    eng = GWEngine(GWServeConfig(solver=SOLVER, tol=TOL))
    prob = _problem(1, 0)
    cold = engine_mod._Request(0, prob, {}, knobs=(8e-3, TOL, 5e-2, 0.5))
    warm = engine_mod._Request(1, prob, {}, knobs=(8e-3, TOL, 5e-2, 0.5))
    warm.warm = object()                  # any cached entry
    assert eng.predicted_hardness(warm) < eng.predicted_hardness(cold) / 10
    easy = engine_mod._Request(2, prob, {}, knobs=(5e-2, TOL, 5e-2, 0.5))
    assert eng.predicted_hardness(warm) < eng.predicted_hardness(easy)


# ---------------------------------------------------------------------------
# serve telemetry: the trailing idle window is accounted
# ---------------------------------------------------------------------------

def test_serve_closes_trailing_idle_window_and_matches_flush_stats():
    """Regression: `serve` opened a device-idle window at the last harvest
    and never folded it into ``device_idle_s`` (flush's epilogue did, so
    the two paths disagreed and a standing server under-reported idle
    forever).  Both paths must leave the clock closed and the same
    telemetry invariants holding on the same stream."""
    stream = _mixed_stream(6, 8200)

    flushed = _mk("pipeline", max_inflight_buckets=2)
    for prob, ctl in stream:
        flushed.submit(*prob, controls=ctl)
    flushed.flush()

    served = _mk("pipeline", max_inflight_buckets=2)
    source = [((*prob,), {"controls": ctl}) for prob, ctl in stream]
    got = run_event_loop(served, source)
    assert len(got) == len(stream)

    for eng in (flushed, served):
        s = eng.stats
        assert eng._idle_since is None          # clock closed, not dangling
        assert eng._inflight == 0
        assert s["flush_wall_s"] > 0.0
        assert 0.0 <= s["device_idle_s"] <= s["flush_wall_s"]
    # the final harvest always strands the device idle for at least the
    # harvest's host time — serve must have captured that trailing window
    assert served.stats["device_idle_s"] > 0.0
    # identical telemetry keys on both paths (incremental admission may
    # legitimately split the same work into MORE dispatches, so counts are
    # not compared — result parity is test_event_loop_matches_flush's job)
    assert set(served.stats) == set(flushed.stats)
    assert served.stats["dispatches"] >= flushed.stats["dispatches"]
