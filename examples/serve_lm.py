"""Serving example: batched generation with prefill + KV-cache decode.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = dataclasses.replace(configs.get_smoke("smollm-360m"),
                              dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, ServeConfig(max_len=128, batch_size=4,
                                             temperature=0.0))
    prompts = np.array([[1, 2, 3, 4, 5, 6, 7, 8]] * 4, np.int32)
    out = engine.generate(prompts, max_new_tokens=16)
    for i, row in enumerate(out):
        print(f"request {i}: {row.tolist()}")
    # greedy decode is deterministic: all 4 identical prompts must agree
    assert (out == out[0]).all()
    print("deterministic batched decode OK")


if __name__ == "__main__":
    main()
