"""Factored-plan GW at scales where the dense plan cannot exist.

Solves entropic GW between two 50k-point clouds with ``plan="lowrank"``:
the coupling is carried as P = Q diag(1/g) Rᵀ with (N,r) factors, the cost
matrices as exact rank-(d+2) factorizations, so no step ever materializes
an (M,N) array.  Then shows the serving engine routing a mixed stream —
small requests to the dense path, large ones to the factored path — through
the same continuous-batching stack.

Run:  PYTHONPATH=src python examples/lowrank_gw.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import GWConfig, PointCloudGeometry, entropic_gw
from repro.serve.engine import GWEngine, GWServeConfig


def main():
    rng = np.random.default_rng(0)

    # --- 50k points, direct solve, factored everything ------------------
    n = 50_000
    gx = PointCloudGeometry(jnp.asarray(rng.normal(size=(n, 3))))
    gy = PointCloudGeometry(jnp.asarray(rng.normal(size=(n, 3))))
    mu = jnp.ones(n) / n
    nu = jnp.ones(n) / n
    cfg = GWConfig(eps=5e-2, outer_iters=40, sinkhorn_iters=50, tol=1e-6,
                   eps_init=0.5, anneal_decay=0.7,
                   plan="lowrank", plan_rank=16)
    t0 = time.perf_counter()
    res = entropic_gw(gx.to_low_rank(), gy.to_low_rank(), mu, nu, cfg)
    jax.block_until_ready(res.value)
    print(f"N={n:,} factored-plan GW: value={float(res.value):.6f}  "
          f"marginal_err={float(res.marginal_err):.2e}  "
          f"iters={int(res.info.outer_iters)}  "
          f"({time.perf_counter() - t0:.1f}s, no (M,N) array built)")
    q, r, g = res.coupling.q, res.coupling.r, res.coupling.g
    print(f"coupling factors: Q{tuple(q.shape)} R{tuple(r.shape)} "
          f"g{tuple(g.shape)} — {q.size + r.size + g.size:,} floats "
          f"vs {n * n:,} for the dense plan\n")

    # --- mixed stream through the engine --------------------------------
    # requests below the threshold run dense; at/above it they are
    # auto-upgraded to the factored plan inside the same bucket loop.
    eng = GWEngine(GWServeConfig(
        solver=GWConfig(eps=5e-2, outer_iters=30, sinkhorn_iters=60,
                        tol=1e-6, plan_rank=8),
        max_batch=4, lowrank_above=512))
    labels = {}
    for m in [96, 128, 2_000, 96, 4_000]:
        pts = rng.normal(size=(m, 2))
        g2 = PointCloudGeometry(jnp.asarray(pts))
        w = jnp.ones(m) / m
        labels[eng.submit(g2, g2, w, w)] = f"n={m}"
    print("engine routing (lowrank_above=512):")
    for rid, out in sorted(eng.flush().items()):
        kind = "factored" if out.plan is None else "dense"
        print(f"  request {rid} ({labels[rid]:7s}) -> {kind:8s} "
              f"value={float(out.value):.6f}  "
              f"merr={float(out.marginal_err):.2e}")


if __name__ == "__main__":
    main()
