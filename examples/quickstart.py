"""Quickstart: entropic GW between two 1D distributions with the FGC fast
gradient (paper §3), FGC-vs-dense parity check, the 2D variant, the batched
many-problems-at-once solver, and the geometry layer (point clouds and
low-rank factored costs through the same engine).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (GWConfig, PointCloudGeometry, entropic_gw,
                        entropic_gw_batch, gw_product, gw_product_dense)
from repro.core.grids import Grid1D, Grid2D


def main():
    # two random distributions on a uniform 1D grid (paper §4.1)
    n = 400
    rng = np.random.default_rng(0)
    mu = jnp.asarray(rng.random(n)); mu = mu / mu.sum()
    nu = jnp.asarray(rng.random(n)); nu = nu / nu.sum()
    grid = Grid1D(n, h=1.0 / (n - 1), k=1)

    cfg = GWConfig(eps=2e-3, outer_iters=10, sinkhorn_iters=200,
                   backend="scan")          # paper-faithful DP backend
    res = entropic_gw(grid, grid, mu, nu, cfg)
    print(f"GW²(μ, ν) = {float(res.value):.6f}")
    print(f"plan marginal error = {float(res.marginal_err):.2e}")

    # the paper's core claim: FGC == dense to machine precision
    dense = entropic_gw(grid, grid, mu, nu,
                        GWConfig(eps=2e-3, outer_iters=10,
                                 sinkhorn_iters=200, backend="dense"))
    diff = float(jnp.linalg.norm(res.plan - dense.plan))
    print(f"‖P_FGC − P_dense‖_F = {diff:.2e}   (paper Table 2 column)")

    # the O(N²) bottleneck product itself
    gamma = mu[:, None] * nu[None, :]
    fast = gw_product(grid, grid, gamma, backend="blocked")
    ref = gw_product_dense(grid, grid, gamma)
    print(f"D_X Γ D_Y max err = {float(jnp.max(jnp.abs(fast - ref))):.2e}")

    # 2D grids (paper §3.1): Kronecker-binomial expansion
    g2 = Grid2D(12, h=1.0 / 11, k=1)
    mu2 = jnp.asarray(rng.random(144)); mu2 = mu2 / mu2.sum()
    nu2 = jnp.asarray(rng.random(144)); nu2 = nu2 / nu2.sum()
    res2 = entropic_gw(g2, g2, mu2, nu2,
                       GWConfig(eps=4e-3, outer_iters=8,
                                sinkhorn_iters=150, backend="cumsum"))
    print(f"2D GW²  = {float(res2.value):.6f} "
          f"(marginal err {float(res2.marginal_err):.1e})")

    # batched solving: many ragged problems, ONE vmapped solve.  Sizes are
    # zero-mass padded to a common shape (exact under log-domain Sinkhorn),
    # so a serving path pays compilation once per shape bucket — see also
    # repro.serve.engine.GWEngine for the queued/bucketed front end.
    sizes = [(60, 80), (80, 60), (50, 75), (80, 80)]
    problems = []
    for i, (m, n2) in enumerate(sizes):
        pm = jnp.asarray(rng.random(m)); pm = pm / pm.sum()
        pn = jnp.asarray(rng.random(n2)); pn = pn / pn.sum()
        problems.append((Grid1D(m, 1.0 / (m - 1), 1),
                         Grid1D(n2, 1.0 / (n2 - 1), 1), pm, pn))
    batch_cfg = GWConfig(eps=2e-3, outer_iters=10, sinkhorn_iters=200,
                         backend="cumsum")
    results = entropic_gw_batch(problems, batch_cfg, pad_to=(80, 80))
    vals = ", ".join(f"{float(r.value):.4f}" for r in results)
    print(f"batched GW² over {len(problems)} ragged problems = [{vals}]")

    # beyond grids: ANY point cloud through the same solver, via the
    # geometry layer.  The dense apply always works; `.to_low_rank()` swaps
    # it for the O(N·r) factored apply (exact for squared Euclidean at
    # rank d+2 — Scetbon et al. 2021).
    pts_a = jnp.asarray(rng.normal(size=(60, 3)))
    pts_b = jnp.asarray(rng.normal(size=(60, 3)) * 0.5)
    mu3 = jnp.asarray(rng.random(60)); mu3 = mu3 / mu3.sum()
    nu3 = jnp.asarray(rng.random(60)); nu3 = nu3 / nu3.sum()
    pc_a, pc_b = PointCloudGeometry(pts_a), PointCloudGeometry(pts_b)
    lr_a, lr_b = pc_a.to_low_rank(), pc_b.to_low_rank()
    dense_res = entropic_gw(pc_a, pc_b, mu3, nu3, batch_cfg)
    lr_res = entropic_gw(lr_a, lr_b, mu3, nu3, batch_cfg)
    print(f"point-cloud GW² = {float(dense_res.value):.6f}  "
          f"(low-rank path: {float(lr_res.value):.6f}, rank {lr_a.rank})")


if __name__ == "__main__":
    main()
