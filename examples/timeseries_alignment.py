"""Paper §4.3: align two time series with FGC-FGW (θ=0.5) and print the
hump correspondence as ASCII art.

Run:  PYTHONPATH=src python examples/timeseries_alignment.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import FGWConfig, entropic_fgw
from repro.core.grids import Grid1D


def two_hump(n, p1, p2, h1=0.5, h2=0.8, w=0.05):
    t = np.linspace(0, 1, n)
    return jnp.asarray(h1 * np.exp(-((t - p1) / w) ** 2)
                       + h2 * np.exp(-((t - p2) / w) ** 2))


def main():
    n = 200
    src = two_hump(n, 0.25, 0.65)
    tgt = two_hump(n, 0.40, 0.80)
    c = jnp.abs(src[:, None] - tgt[None, :])      # signal-strength cost
    grid = Grid1D(n, 1.0 / (n - 1), 1)
    mu = jnp.full((n,), 1.0 / n, jnp.float64)

    cfg = FGWConfig(eps=2e-3, outer_iters=10, sinkhorn_iters=300,
                    backend="scan", theta=0.5)
    res = entropic_fgw(grid, grid, c, mu, mu, cfg)
    plan = np.asarray(res.plan)
    print(f"FGW value = {float(res.value):.6f}")

    # where do the humps go?
    for name, peak in (("small hump", int(np.argmax(np.asarray(src[:n//2])))),
                       ("tall hump", n // 2
                        + int(np.argmax(np.asarray(src[n//2:]))))):
        mapped = int(np.argmax(plan[peak]))
        print(f"{name}: source t={peak/(n-1):.3f} → target "
              f"t={mapped/(n-1):.3f}")

    # coarse ASCII of the transport plan (paper Fig. 3 right)
    step = n // 40
    print("\ntransport plan (rows=source, cols=target):")
    for i in range(0, n, step * 2):
        row = plan[i, ::step]
        print("".join("#" if v > row.max() * 0.5 and row.max() > 1e-8
                      else "." for v in row))


if __name__ == "__main__":
    main()
