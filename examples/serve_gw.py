"""Serving example: continuous-batching GW solving with per-request ε.

A mixed-difficulty request stream (easy ε=0.05 → the paper's hard ε=0.002,
with ε-annealing) flows through `GWEngine`'s slot scheduler: bounded
segments of outer steps per dispatch, converged lanes harvested and their
slots refilled between segments, hardest-predicted requests admitted first.
The flush-barrier scheduler solves the same stream for comparison — results
must agree bit-for-bit (scheduling changes WHEN work runs, never what it
computes).

Run:  PYTHONPATH=src python examples/serve_gw.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import GWConfig
from repro.core.grids import Grid1D
from repro.serve.engine import GWEngine, GWServeConfig


def measure(n, seed):
    r = np.random.default_rng(seed)
    u = r.random(n) + 0.05
    return jnp.asarray(u / u.sum())


def run(scheduler, stream, solver):
    eng = GWEngine(GWServeConfig(solver=solver, max_batch=4, size_bucket=48,
                                 tol=1e-4, scheduler=scheduler))
    rids = {eng.submit(g, g, mu, nu, eps=eps, eps_init=5e-2): eps
            for g, mu, nu, eps in stream}
    t0 = time.perf_counter()
    out = eng.flush()
    jax.block_until_ready([r.plan for r in out.values()])
    return eng, rids, out, time.perf_counter() - t0


def main():
    n = 48
    g = Grid1D(n, 1.0 / (n - 1), 1)
    eps_cycle = [5e-2, 2e-2, 8e-3, 2e-3]
    stream = [(g, measure(n, 2 * i), measure(n, 2 * i + 1),
               eps_cycle[i % 4]) for i in range(12)]
    solver = GWConfig(eps=2e-3, outer_iters=60, sinkhorn_iters=400)

    run("continuous", stream, solver)          # warm the jit caches
    run("barrier", stream, solver)
    eng, rids, out, wall_c = run("continuous", stream, solver)
    _, _, out_b, wall_b = run("barrier", stream, solver)

    print(f"{'req':>4} {'eps':>7} {'outer':>6} {'inner':>6} "
          f"{'marginal err':>13} conv")
    for rid in sorted(out):
        info = out[rid].info
        print(f"{rid:4d} {rids[rid]:7.0e} {int(info.outer_iters):6d} "
              f"{int(info.inner_iters):6d} "
              f"{float(info.marginal_err):13.2e} "
              f"{bool(info.converged)}")
    s = eng.stats
    print(f"\ncontinuous: {s['dispatches']} dispatches, "
          f"{s['refills']} refills, {s['repacks']} repacks; "
          f"executed/useful inner {s['executed_inner']}/{s['useful_inner']}")
    print(f"wall: barrier {wall_b:.3f}s → continuous {wall_c:.3f}s")
    # scheduling must not change results
    same = all(bool(jnp.array_equal(out[r].plan, out_b[r].plan))
               for r in out)
    assert same and set(out) == set(out_b)
    print("barrier and continuous schedules returned identical plans OK")


if __name__ == "__main__":
    main()
