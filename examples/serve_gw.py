"""Serving example: continuous-batching GW solving with per-request ε.

A mixed-difficulty request stream (easy ε=0.05 → the paper's hard ε=0.002,
with ε-annealing) flows through `GWEngine`'s slot scheduler: bounded
segments of outer steps per dispatch, converged lanes harvested and their
slots refilled between segments, hardest-predicted requests admitted first.
The flush-barrier scheduler solves the same stream for comparison — results
must agree bit-for-bit (scheduling changes WHEN work runs, never what it
computes).

Part two upgrades to the PR-8 serving surface: the "pipeline" scheduler
keeps several BUCKETS' segment dispatches in flight at once (async
dispatch, donated carries), and the geometry-fingerprint plan cache
answers exact repeats without touching the device and warm-starts
near-repeats from the cached coupling.

Run:  PYTHONPATH=src python examples/serve_gw.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import GWConfig
from repro.core.grids import Grid1D
from repro.serve.engine import GWEngine, GWServeConfig


def measure(n, seed):
    r = np.random.default_rng(seed)
    u = r.random(n) + 0.05
    return jnp.asarray(u / u.sum())


def run(scheduler, stream, solver):
    eng = GWEngine(GWServeConfig(solver=solver, max_batch=4, size_bucket=48,
                                 tol=1e-4, scheduler=scheduler))
    rids = {eng.submit(g, g, mu, nu, eps=eps, eps_init=5e-2): eps
            for g, mu, nu, eps in stream}
    t0 = time.perf_counter()
    out = eng.flush()
    jax.block_until_ready([r.plan for r in out.values()])
    return eng, rids, out, time.perf_counter() - t0


def main():
    n = 48
    g = Grid1D(n, 1.0 / (n - 1), 1)
    eps_cycle = [5e-2, 2e-2, 8e-3, 2e-3]
    stream = [(g, measure(n, 2 * i), measure(n, 2 * i + 1),
               eps_cycle[i % 4]) for i in range(12)]
    solver = GWConfig(eps=2e-3, outer_iters=60, sinkhorn_iters=400)

    run("continuous", stream, solver)          # warm the jit caches
    run("barrier", stream, solver)
    eng, rids, out, wall_c = run("continuous", stream, solver)
    _, _, out_b, wall_b = run("barrier", stream, solver)

    print(f"{'req':>4} {'eps':>7} {'outer':>6} {'inner':>6} "
          f"{'marginal err':>13} conv")
    for rid in sorted(out):
        info = out[rid].info
        print(f"{rid:4d} {rids[rid]:7.0e} {int(info.outer_iters):6d} "
              f"{int(info.inner_iters):6d} "
              f"{float(info.marginal_err):13.2e} "
              f"{bool(info.converged)}")
    s = eng.stats
    print(f"\ncontinuous: {s['dispatches']} dispatches, "
          f"{s['refills']} refills, {s['repacks']} repacks; "
          f"executed/useful inner {s['executed_inner']}/{s['useful_inner']}")
    print(f"wall: barrier {wall_b:.3f}s → continuous {wall_c:.3f}s")
    # scheduling must not change results
    same = all(bool(jnp.array_equal(out[r].plan, out_b[r].plan))
               for r in out)
    assert same and set(out) == set(out_b)
    print("barrier and continuous schedules returned identical plans OK")

    pipeline_and_cache_demo()


def pipeline_and_cache_demo():
    """Multi-bucket pipelined flush + the plan cache on repeat traffic."""
    from repro.core.geometry import PointCloudGeometry

    print("\n--- pipelined serving + plan cache ---")
    solver = GWConfig(eps=2e-1, outer_iters=60, sinkhorn_iters=200,
                      sinkhorn_chunk=25, backend="dense", eps_init=1.0,
                      anneal_decay=0.7)
    eng = GWEngine(GWServeConfig(
        solver=solver, max_batch=4, size_bucket=16, tol=1e-4,
        scheduler="pipeline", max_inflight_buckets=2,
        cache_capacity=64, cache_near_tol=1e-3))

    r = np.random.default_rng(0)
    probs = []
    for m, n in [(12, 16), (16, 12), (24, 24)]:     # three buckets
        gx = PointCloudGeometry(jnp.asarray(r.normal(size=(m, 2))))
        gy = PointCloudGeometry(jnp.asarray(r.normal(size=(n, 2))))
        mu, nu = r.random(m) + 0.5, r.random(n) + 0.5
        probs.append((gx, gy, jnp.asarray(mu / mu.sum()),
                      jnp.asarray(nu / nu.sum())))

    cold_rids = [eng.submit(*p) for p in probs]
    cold = eng.flush()
    s = eng.stats
    print(f"cold flush: {s['dispatches']} dispatches at depths "
          f"{s['dispatch_depth']}, outer "
          f"{[int(cold[r].info.outer_iters) for r in cold_rids]}")

    # exact repeats: answered from the cache, zero device work
    hot_rids = [eng.submit(*p) for p in probs]
    hot = eng.flush()
    assert eng.stats["dispatches"] == 0
    assert all(jnp.array_equal(hot[h].plan, cold[c].plan)
               for h, c in zip(hot_rids, cold_rids))
    print(f"exact repeats: {eng.stats['cache_hits']} cache hits, "
          f"{eng.stats['dispatches']} dispatches (bit-identical plans)")

    # near repeats (points nudged far below near_tol): warm-started from
    # the cached coupling — the annealing ramp is skipped entirely
    warm_rids = [eng.submit(PointCloudGeometry(gx.points + 1e-7),
                            PointCloudGeometry(gy.points + 1e-7), mu, nu)
                 for gx, gy, mu, nu in probs]
    warm = eng.flush()
    print(f"near repeats: {eng.stats['cache_warm_starts']} warm starts, "
          f"outer {[int(warm[r].info.outer_iters) for r in warm_rids]} "
          f"(vs {[int(cold[r].info.outer_iters) for r in cold_rids]} cold)")


if __name__ == "__main__":
    main()
