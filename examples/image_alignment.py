"""Paper §4.4: 2D FGW image alignment with FGC — digit invariances
(translation / rotation / reflection) and the deformed-shape task.

Run:  PYTHONPATH=src python examples/image_alignment.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import synthetic_digit, synthetic_horse
from repro.core import FGWConfig, entropic_fgw
from repro.core.grids import Grid2D


def align(img_a, img_b, n, theta, h=1.0):
    mu = jnp.ravel(img_a); mu = mu / mu.sum()
    nu = jnp.ravel(img_b); nu = nu / nu.sum()
    c = jnp.abs(jnp.ravel(img_a)[:, None] - jnp.ravel(img_b)[None, :])
    g = Grid2D(n, h, 1)                      # Manhattan pixel metric (k=1)
    cfg = FGWConfig(eps=5e-1, outer_iters=8, sinkhorn_iters=100,
                    backend="cumsum", sinkhorn_mode="log", theta=theta)
    return entropic_fgw(g, g, c, mu, nu, cfg)


def main():
    n = 20
    img = synthetic_digit(n)
    a = np.asarray(img)
    transforms = {"translation": np.roll(a, (2, 2), (0, 1)),
                  "rotation": np.rot90(a).copy(),
                  "reflection": a[:, ::-1].copy()}
    print("digit invariances (paper §4.4.1, θ=0.1):")
    vals = {}
    for name, timg in transforms.items():
        res = align(img, jnp.asarray(timg), n, theta=0.1)
        vals[name] = float(res.value)
        print(f"  {name:12s} FGW value = {vals[name]:.6f}")
    spread = max(vals.values()) - min(vals.values())
    print(f"  isometry-invariance spread = {spread:.2e} (should be ~0)\n")

    print("deformed shape alignment (paper §4.4.2, θ=0.8):")
    m = 24
    res = align(synthetic_horse(m, 0.0), synthetic_horse(m, 1.0), m,
                theta=0.8, h=100.0 / m)
    plan = np.asarray(res.plan)
    diag_mass = float(np.trace(plan)) / float(plan.sum())
    print(f"  FGW value = {float(res.value):.4f}; "
          f"mass on identity map = {diag_mass:.2f}")


if __name__ == "__main__":
    main()
