"""End-to-end driver: train a ~100M-param smollm-family model for a few
hundred steps on the synthetic pipeline, with checkpoint/restart and the
optional FGC-FGW alignment (distillation) loss.

Run (full, ~100M params — slow on 1 CPU core but real):
  PYTHONPATH=src python examples/train_lm.py --steps 300
Fast sanity (reduced width):
  PYTHONPATH=src python examples/train_lm.py --steps 60 --small

This is a thin veneer over repro.launch.train (the production driver);
see also: python -m repro.launch.train --help
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="reduced config (seconds instead of hours on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--gw-align", action="store_true",
                    help="add the FGC-FGW sequence-alignment loss")
    args = ap.parse_args()

    argv = ["--arch", "smollm-360m",
            "--steps", str(args.steps),
            "--global-batch", "4" if not args.small else "8",
            "--seq", "256" if not args.small else "64",
            "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100",
            "--log-every", "10"]
    if args.small:
        argv.append("--smoke")
    if args.gw_align:
        argv += ["--gw-align-weight", "0.1"]
    # ~100M: the full smollm-360m is 360M which is heavy for CPU; the
    # driver's --smoke flag switches to the reduced config. For the
    # "~100M for a few hundred steps" e2e run use full config on TPU;
    # on this CPU container --small is the supported path.
    train_driver.main(argv)


if __name__ == "__main__":
    main()
